"""Morph over a real(istic) network: the event-driven runtime end to end.

Runs a small Morph population twice — once on an ideal network (which is
provably identical to the synchronous runner) and once on a flaky WAN
with drops, stragglers and churn — and prints the wall-clock-domain
story: time-to-accuracy, staleness, messages lost.

    PYTHONPATH=src python examples/async_morph.py

Scale via the environment for smoke runs (tools/run_examples.py):
EXAMPLE_NODES / EXAMPLE_ROUNDS.
"""
import os

import numpy as np

from repro.core import MorphConfig, MorphProtocol
from repro.data import (StackedBatcher, dirichlet_partition,
                        make_image_classification, train_test_split)
from repro.models.cnn import cnn_loss, cnn_params
from repro.netsim import (AsyncConfig, AsyncRunner, FaultConfig, FaultModel,
                          profiles)
from repro.optim import sgd

N = int(os.environ.get("EXAMPLE_NODES", "8"))
ROUNDS = int(os.environ.get("EXAMPLE_ROUNDS", "20"))
K = 2


def build_runner(profile, faults):
    rng = np.random.default_rng(0)
    ds = make_image_classification(2000, num_classes=10, image_size=16,
                                   noise=3.0, seed=0)
    tr, te = train_test_split(ds, 0.2, seed=0)
    parts = dirichlet_partition(tr.labels, N, 0.1, rng)
    return AsyncRunner(
        init_fn=lambda key: cnn_params(key, in_channels=3, num_classes=10,
                                       image_size=16, width=12),
        loss_fn=cnn_loss, eval_fn=cnn_loss, optimizer=sgd(0.05),
        batcher=StackedBatcher(tr, parts, 8, seed=0),
        test_batch={"images": te.images[:256], "labels": te.labels[:256]},
        strategy=MorphProtocol(MorphConfig(n=N, k=K, seed=0)),
        cfg=AsyncConfig(n_nodes=N, rounds=ROUNDS, eval_every=5,
                        compute_time_s=1.0, mix_timeout_s=3.0),
        profile=profile, faults=faults)


def report(tag, runner, log):
    stats = runner.transport.stats
    last = log.last()
    print(f"\n== {tag} ==")
    print(f"  virtual time       {last.t:8.1f} s for {ROUNDS} rounds")
    print(f"  final accuracy     {last.mean_accuracy:8.3f}  "
          f"(inter-node var {last.internode_variance:.3f})")
    tta = log.time_to_accuracy(0.5)
    print(f"  time to 50% acc    "
          f"{tta:8.1f} s" if tta is not None else
          "  time to 50% acc        not reached")
    print(f"  model payload      {last.model_bytes / 1e6:8.2f} MB, "
          f"control {last.control_bytes / 1e3:.1f} kB")
    print(f"  messages dropped   {stats.dropped:8d}  "
          f"(peak in flight {stats.peak_in_flight})")
    print(f"  model staleness    {log.staleness_mean():8.2f} rounds mean  "
          f"histogram {dict(sorted(log.staleness_hist.items()))}")
    print(f"  realized in-degree max "
          f"{max(runner.realized_indegrees)} (cap k={K})")


def main():
    print("ideal network (== synchronous runner, bit for bit) ...")
    runner = build_runner(profiles.ideal(), FaultModel.none(N))
    log = runner.run(progress=lambda r: print(
        f"  t={r.t:6.1f}s round {r.rnd:3d} acc {r.mean_accuracy:.3f}"))
    report("ideal", runner, log)

    print("\nflaky WAN + stragglers + churn ...")
    horizon = ROUNDS * 1.5
    faults = FaultModel(FaultConfig(
        straggler_fraction=0.25, straggler_slowdown=2.0,
        churn_fraction=0.25, mean_downtime_s=4.0, horizon_s=horizon,
        seed=7), N)
    runner = build_runner(
        profiles.flaky_wan(N, partition_at=horizon * 0.3,
                           partition_len=horizon * 0.15, seed=7), faults)
    log = runner.run(progress=lambda r: print(
        f"  t={r.t:6.1f}s round {r.rnd:3d} acc {r.mean_accuracy:.3f} "
        f"dropped {r.dropped} dead {r.dead}"))
    report("flaky WAN", runner, log)


if __name__ == "__main__":
    main()
