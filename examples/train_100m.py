"""End-to-end driver: train a ~100M-param llama-family model with Morph
for a few hundred decentralized rounds.

The config is the llama3.2 family scaled to ~110M params (12 layers,
d_model 768, GQA 12/4, vocab 32768) — real model, real optimizer, real
Morph control plane.  On CPU each round is seconds; on a TPU slice pass
--mesh single to shard it with the node_dp policy.

  PYTHONPATH=src python examples/train_100m.py --rounds 300
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import make_token_stream
from repro.data.pipeline import TokenBatcher
from repro.dlrt import MorphHParams, init_train_state, make_train_step
from repro.models import model as model_api
from repro.optim import adamw, linear_warmup_cosine


def build_cfg():
    base = get_config("llama3.2-3b")
    return dataclasses.replace(
        base, name="llama-100m", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=32768, param_dtype="float32",
        compute_dtype="float32", remat=False, n_nodes=4)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--delta-r", type=int, default=5)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = build_cfg()
    opt = adamw(linear_warmup_cosine(3e-4, 20, args.rounds))
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt, args.nodes)
    n_params = model_api.param_count(
        jax.tree_util.tree_map(lambda x: x[0], state.params))
    print(f"model: {cfg.name}, {n_params / 1e6:.1f}M params/node, "
          f"{args.nodes} nodes")

    hp = MorphHParams(k=min(2, args.nodes - 1),
                      view_size=min(3, args.nodes - 1))
    steps = {True: jax.jit(make_train_step(cfg, opt, hp,
                                           do_topology=True)),
             False: jax.jit(make_train_step(cfg, opt, hp,
                                            do_topology=False))}
    batchers = [TokenBatcher(
        make_token_stream(300_000, cfg.vocab_size, seed=i,
                          concentration=0.02), args.batch, args.seq,
        seed=i) for i in range(args.nodes)]

    t0 = time.time()
    for rnd in range(args.rounds):
        node_batches = [b.next() for b in batchers]
        batch = {k: jnp.asarray(np.stack([nb[k] for nb in node_batches]))
                 for k in ("tokens", "labels")}
        state, metrics = steps[rnd % args.delta_r == 0](state, batch)
        if rnd % args.log_every == 0 or rnd == args.rounds - 1:
            print(f"round {rnd:4d}  loss {float(metrics['loss']):.4f}  "
                  f"({(time.time() - t0):.0f}s)", flush=True)
    print(f"trained {args.rounds} rounds in {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
