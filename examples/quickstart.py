"""Quickstart: decentralized training with Morph in ~60 lines.

Eight DL nodes, each with its own non-IID token stream, train a reduced
llama-family model.  The whole Morph round — local step, Eq.-3 pairwise
similarity, Eq.-5 diversity selection, college-admission matching,
uniform mixing — runs as ONE jitted superstep.

  PYTHONPATH=src python examples/quickstart.py

Scale via the environment for smoke runs (tools/run_examples.py):
EXAMPLE_NODES / EXAMPLE_ROUNDS.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import make_token_stream
from repro.data.pipeline import TokenBatcher
from repro.dlrt import MorphHParams, init_train_state, make_train_step
from repro.optim import sgd

N_NODES = int(os.environ.get("EXAMPLE_NODES", "8"))
ROUNDS = int(os.environ.get("EXAMPLE_ROUNDS", "60"))
BATCH, SEQ, DELTA_R = 8, 64, 5

cfg = get_config("llama3.2-3b").reduced()      # same family, smoke scale
opt = sgd(0.1)
state = init_train_state(jax.random.PRNGKey(0), cfg, opt, N_NODES)

# Each node gets a different Markov "dialect" => non-IID local data.
batchers = [TokenBatcher(make_token_stream(
    60_000, cfg.vocab_size, seed=i, concentration=0.03 + 0.02 * (i % 4)),
    BATCH, SEQ, seed=i) for i in range(N_NODES)]

hp = MorphHParams(k=3, view_size=5, beta=500.0)   # paper defaults
step_topo = jax.jit(make_train_step(cfg, opt, hp, do_topology=True))
step_fixed = jax.jit(make_train_step(cfg, opt, hp, do_topology=False))

for rnd in range(ROUNDS):
    node_batches = [b.next() for b in batchers]
    batch = {k: jnp.asarray(np.stack([nb[k] for nb in node_batches]))
             for k in ("tokens", "labels")}
    # Alg. 2: re-negotiate the topology every Delta_r rounds.
    step = step_topo if rnd % DELTA_R == 0 else step_fixed
    state, metrics = step(state, batch)
    if rnd % 10 == 0 or rnd == ROUNDS - 1:
        deg = np.asarray(state.morph.edges.sum(1))
        known = int(state.morph.known.sum())
        print(f"round {rnd:3d}  loss {float(metrics['loss']):.4f}  "
              f"in-degree {deg.min()}..{deg.max()}  "
              f"known-peer edges {known}")

print("\nFinal in-edge matrix (row i <- senders):")
print(np.asarray(state.morph.edges).astype(int))
